"""CLI for the static-analysis gate: ``python -m repro.analysis``.

Runs the §15 passes and exits non-zero on any unsuppressed finding
from a GATED pass:

    python -m repro.analysis --all            # everything (the CI gate)
    python -m repro.analysis --only lint      # one pass
    python -m repro.analysis --all --json     # machine-readable to stdout
    python -m repro.analysis --all --json out.json

Passes: ``determinism`` (traced-jaxpr audit), ``kernels`` (Pallas VMEM
/ alignment checker), ``lint`` (AST recompile-hazard lint) — all three
gate. ``imports`` (dead-code report) is informational and never gates.

Exit codes: 0 clean, 1 unsuppressed gated findings, 2 usage error
(unknown ``--only`` name, listing the valid ones — the benchmarks.run
convention).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List

from repro.analysis.visitor import Finding

GATED = ("determinism", "kernels", "lint")
PASSES = GATED + ("imports",)


def _run_determinism(hw: str) -> Dict:
    from repro.analysis import determinism
    findings, audited, skipped = determinism.audit_all()
    return {"findings": findings, "audited": audited, "skipped": skipped}


def _run_kernels(hw: str) -> Dict:
    from repro.analysis import kernels
    findings, n_plans = kernels.audit_all(hw)
    return {"findings": findings, "plans": n_plans}


def _run_lint(hw: str) -> Dict:
    from repro.analysis import lint
    findings, n_files = lint.audit_all()
    return {"findings": findings, "files": n_files}


def _run_imports(hw: str) -> Dict:
    from repro.analysis import imports
    return {"findings": [], "report": imports.report()}


_RUNNERS = {"determinism": _run_determinism, "kernels": _run_kernels,
            "lint": _run_lint, "imports": _run_imports}


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static determinism auditor + Pallas kernel checker")
    ap.add_argument("--all", action="store_true",
                    help="run every pass (default when --only is absent)")
    ap.add_argument("--only", default=None, metavar="PASS[,PASS...]",
                    help=f"run a subset of passes; valid: {', '.join(PASSES)}")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="PATH",
                    help="emit a JSON report to PATH (default stdout)")
    ap.add_argument("--hw-profile", default=None,
                    help="roofline hardware profile for the kernels pass")
    args = ap.parse_args(argv)

    if args.only:
        names = [p.strip() for p in args.only.split(",") if p.strip()]
        unknown = [p for p in names if p not in PASSES]
        if unknown:
            print(f"error: unknown pass name(s): {', '.join(unknown)}; "
                  f"valid passes: {', '.join(PASSES)}", file=sys.stderr)
            return 2
    else:
        names = list(PASSES)

    results: Dict[str, Dict] = {}
    for name in names:
        results[name] = _RUNNERS[name](args.hw_profile)

    gating: List[Finding] = []
    suppressed = 0
    for name, res in results.items():
        for f in res["findings"]:
            if f.suppressed:
                suppressed += 1
            elif name in GATED:
                gating.append(f)

    if args.json is not None:
        payload = {
            "ok": not gating,
            "passes": {
                name: {
                    "gated": name in GATED,
                    "findings": [f.to_dict() for f in res["findings"]],
                    **{k: v for k, v in res.items() if k != "findings"},
                }
                for name, res in results.items()
            },
        }
        text = json.dumps(payload, indent=2, sort_keys=True)
        if args.json == "-":
            print(text)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(text)

    # Human summary on stderr so --json to stdout stays parseable.
    out = sys.stderr if args.json == "-" else sys.stdout
    for name, res in results.items():
        extras = []
        if "audited" in res:
            extras.append(f"{len(res['audited'])} artifacts")
            if res["skipped"]:
                extras.append(f"skipped: {', '.join(res['skipped'])}")
        if "plans" in res:
            extras.append(f"{res['plans']} plans")
        if "files" in res:
            extras.append(f"{res['files']} files")
        n_find = len(res["findings"])
        gate = "gated" if name in GATED else "report-only"
        print(f"[{name}] {gate}: {n_find} finding(s)"
              + (f" ({', '.join(extras)})" if extras else ""), file=out)
        for f in res["findings"]:
            print(f"  {f}", file=out)
        if name == "imports":
            from repro.analysis import imports as imp_mod
            for line in imp_mod.render(res["report"]).splitlines():
                print(f"  {line}", file=out)

    if gating:
        print(f"\nFAIL: {len(gating)} unsuppressed finding(s) "
              f"({suppressed} suppressed)", file=out)
        return 1
    print(f"\nOK: 0 unsuppressed findings ({suppressed} suppressed)",
          file=out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
