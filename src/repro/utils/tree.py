"""Pytree helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
               for x in jax.tree.leaves(tree))


def tree_norm(tree) -> jax.Array:
    leaves = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def check_finite(tree) -> jax.Array:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree.leaves(tree)]
    return jnp.all(jnp.stack(leaves))
