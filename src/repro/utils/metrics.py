"""Clustering quality metrics (accuracy up to label permutation, as the
paper reports in Table 1 / Figure 1)."""
from __future__ import annotations

import numpy as np


def confusion(pred, true, k: int) -> np.ndarray:
    """(k, k) confusion counts over valid (label >= 0) entries."""
    pred = np.asarray(pred).reshape(-1)
    true = np.asarray(true).reshape(-1)
    m = (pred >= 0) & (true >= 0)
    cm = np.zeros((k, k), np.int64)
    np.add.at(cm, (pred[m], true[m]), 1)
    return cm


def clustering_accuracy(pred, true, k: int) -> float:
    """Accuracy under the best label permutation (Hungarian matching)."""
    from scipy.optimize import linear_sum_assignment
    cm = confusion(pred, true, k)
    rows, cols = linear_sum_assignment(-cm)
    total = cm.sum()
    return float(cm[rows, cols].sum() / max(total, 1))
