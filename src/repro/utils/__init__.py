from repro.utils.metrics import clustering_accuracy, confusion  # noqa: F401
from repro.utils.tree import param_count, tree_bytes  # noqa: F401
