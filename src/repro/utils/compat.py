"""JAX version compatibility shims.

The production code targets the current public APIs (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``); older pinned containers only
ship ``jax.experimental.shard_map`` and a ``make_mesh`` without
``axis_types``. Every mesh/shard_map construction in the repo routes
through here so the whole stack — including the distributed k-FED paths
— runs on both.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))
