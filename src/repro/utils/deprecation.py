"""Warn-once deprecation registry for legacy federation entry points.

Every pre-`fed.api` entry point (``core.kfed.kfed``,
``core.distributed.kfed_shard_map``, ``fed.engine.run_round`` /
``run_round_async``, ``fed.stream.AttachService.from_round`` /
``restore``, ``launch.serve.make_kfed_attach``) now delegates to the
declarative ``fed.api.Session`` surface and announces its replacement
with exactly ONE ``DeprecationWarning`` per process — noisy enough to
see once, quiet enough that long-running services and test suites are
not flooded.

Lives in ``utils`` (not ``fed.api``) so shims anywhere in the layering
can import it without creating cycles.
"""
from __future__ import annotations

import warnings

_emitted: set = set()


def warn_legacy(name: str, replacement: str) -> None:
    """Emit one DeprecationWarning per process for ``name``, naming the
    ``fed.api`` replacement. Subsequent calls are free."""
    if name in _emitted:
        return
    _emitted.add(name)
    warnings.warn(
        f"repro legacy entry point {name} is deprecated; use "
        f"{replacement} (repro.fed.api) instead. This warning is "
        f"emitted once per process.",
        DeprecationWarning, stacklevel=3)


def reset_legacy_warnings() -> None:
    """Forget which warnings were emitted (tests only)."""
    _emitted.clear()
